"""Tests for repro.analysis: Layer-1 source rules, suppression machinery,
the Layer-2 compiled-program verifier, and the CLI contract.

Layer-1 fixtures are inline source blobs analyzed under *virtual* paths
(``analyze_source(src, "src/repro/core/simulate.py")``), so each rule is
exercised against the module classification it guards without touching
real files.  The deliberate-break tests at the bottom are the acceptance
demo: a smuggled ``psum`` or an inline epsilon fails the pass with the
rule code / program key and location — no device program ever executes.
"""

import json
import os
import pathlib
import subprocess
import sys

import pytest

from repro.analysis import (Baseline, analyze_source, load_baseline,
                            run_source_analysis)
from repro.analysis.engine import BaselineEntry
from repro.analysis.report import render_json, summary_table

REPO = pathlib.Path(__file__).resolve().parents[1]

LIB = "src/repro/engine/foo.py"          # generic library module
DEVICE = "src/repro/kernels/foo.py"      # device-path module
GUARDED = "src/repro/core/simulate.py"   # knife-edge module


def _codes(src, path):
    return [f.code for f in analyze_source(src, path)]


# --------------------------------------------------------------------------
# Per-rule good / bad fixtures
# --------------------------------------------------------------------------

def test_rpr001_timing_fires_outside_trace():
    bad = "import time\nt0 = time.perf_counter()\n"
    assert _codes(bad, LIB) == ["RPR001"]
    assert _codes("from time import perf_counter\n", LIB) == ["RPR001"]


def test_rpr001_silent_in_trace_module_and_on_spans():
    src = "import time\nt0 = time.perf_counter_ns()\n"
    assert _codes(src, "src/repro/obs/trace.py") == []
    good = ("from repro.obs import span\n"
            "with span('phase') as sp:\n    pass\n")
    assert _codes(good, LIB) == []


def test_rpr002_unbounded_cache_fires():
    bad = ("import functools\n"
           "@functools.lru_cache(maxsize=None)\n"
           "def f():\n    return 1\n")
    assert _codes(bad, LIB) == ["RPR002"]
    bare = ("import functools\n"
            "@functools.lru_cache\ndef f():\n    return 1\n"
            "@functools.cache\ndef g():\n    return 2\n")
    assert _codes(bare, LIB) == ["RPR002", "RPR002"]


def test_rpr002_bounded_cache_silent():
    good = ("import functools\n"
            "@functools.lru_cache(maxsize=64)\n"
            "def f():\n    return 1\n")
    assert _codes(good, LIB) == []


def test_rpr003_float64_on_device_path_fires():
    assert _codes("import jax.numpy as jnp\nD = jnp.float64\n",
                  DEVICE) == ["RPR003"]
    jit_leak = ("import jax\n"
                "def step(x):\n    return x.astype('float64')\n"
                "fn = jax.jit(step)\n")
    assert _codes(jit_leak, DEVICE) == ["RPR003"]
    assert _codes("import jax\njax.config.update('jax_enable_x64', True)\n",
                  DEVICE) == ["RPR003"]


def test_rpr003_host_numpy_f64_oracle_allowed():
    # np.float64 outside any jit-reachable function is the documented
    # host-side oracle boundary — not a device-path leak.
    good = ("import numpy as np\n"
            "def oracle(x):\n    return np.asarray(x, dtype=np.float64)\n")
    assert _codes(good, DEVICE) == []
    # ...and float64 off the device path is out of scope entirely.
    assert _codes("import jax.numpy as jnp\nD = jnp.float64\n",
                  "src/repro/core/cost.py") == []


def test_rpr004_inline_epsilon_fires_with_location():
    src = "def clip(x):\n    if x > 1e-9:\n        return 0.0\n    return x\n"
    findings = analyze_source(src, GUARDED)
    assert [(f.code, f.location) for f in findings] == [
        ("RPR004", f"{GUARDED}:2")]


def test_rpr004_named_guard_silences():
    good = ("FLEX_REL = 1e-6\n"
            "def clip(x, y):\n"
            "    if x > FLEX_REL * 1e-5:\n        return 0.0\n    return x\n")
    assert _codes(good, GUARDED) == []
    # large-magnitude literals are not knife-edge tolerances
    assert _codes("def f(x):\n    return x > 0.5\n", GUARDED) == []
    # same comparison outside the guarded modules is out of scope
    assert _codes("def f(x):\n    return x > 1e-9\n", LIB) == []


def test_rpr005_host_sync_in_jit_reachable_fires():
    bad = ("import jax\n"
           "def _inner(x):\n    return float(x[0])\n"
           "def step(x):\n    return _inner(x) + 1.0\n"
           "fn = jax.jit(step)\n")
    assert _codes(bad, LIB) == ["RPR005"]
    item = ("import jax\n"
            "@jax.jit\ndef step(x):\n    return x.sum().item()\n")
    assert _codes(item, LIB) == ["RPR005"]


def test_rpr005_host_sync_outside_jit_graph_silent():
    good = ("import jax\n"
            "def step(x):\n    return x + 1.0\n"
            "fn = jax.jit(step)\n"
            "def report(x):\n    return float(x[0])\n")
    assert _codes(good, LIB) == []


def test_rpr006_donation_outside_whitelist_fires():
    src = "import jax\nfn = jax.jit(f, donate_argnums=(0,))\n"
    assert _codes(src, LIB) == ["RPR006"]
    # learn/replay.py is the §11 whitelist: same source, no finding.
    assert _codes(src, "src/repro/learn/replay.py") == []


def test_rpr007_callbacks_on_device_path_fire():
    assert _codes("import jax\ny = jax.pure_callback(f, s, x)\n",
                  DEVICE) == ["RPR007"]
    assert _codes("import jax\njax.debug.print('x={}', x)\n",
                  DEVICE) == ["RPR007"]
    assert _codes("from jax.experimental import io_callback\n",
                  DEVICE) == ["RPR007"]
    # off the device path the same source is out of scope
    assert _codes("import jax\ny = jax.pure_callback(f, s, x)\n",
                  "src/repro/core/foo.py") == []


def test_rpr000_syntax_error():
    findings = analyze_source("def broken(:\n", LIB)
    assert [f.code for f in findings] == ["RPR000"]


# --------------------------------------------------------------------------
# Suppression: inline noqa + content-keyed baseline
# --------------------------------------------------------------------------

def test_noqa_suppresses_matching_code():
    src = "def f(x):\n    return x > 1e-9  # repro: noqa RPR004\n"
    assert _codes(src, GUARDED) == []
    bare = "def f(x):\n    return x > 1e-9  # repro: noqa\n"
    assert _codes(bare, GUARDED) == []


def test_noqa_other_code_does_not_suppress():
    src = "def f(x):\n    return x > 1e-9  # repro: noqa RPR001\n"
    assert _codes(src, GUARDED) == ["RPR004"]


def test_baseline_roundtrip_is_content_keyed(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    target = mod / "simulate.py"
    target.write_text("def g(x):\n    return x > 1e-9\n")

    active, baselined = run_source_analysis(["src"], tmp_path, Baseline())
    assert [f.code for f in active] == ["RPR004"] and baselined == []

    bl_path = tmp_path / "analysis-baseline.json"
    bl_path.write_text(json.dumps({"version": 1, "entries": [{
        "rule": "RPR004", "path": "src/repro/core/simulate.py",
        "line_text": "return x > 1e-9", "justification": "fixture"}]}))
    active, baselined = run_source_analysis(
        ["src"], tmp_path, load_baseline(bl_path))
    assert active == [] and [f.code for f in baselined] == ["RPR004"]

    # shifting the finding to a different line number must not invalidate
    # the entry — the baseline keys on (rule, path, stripped line text).
    target.write_text("# padding\n\n\ndef g(x):\n    return x > 1e-9\n")
    active, baselined = run_source_analysis(
        ["src"], tmp_path, load_baseline(bl_path))
    assert active == [] and len(baselined) == 1
    assert baselined[0].line == 5


def test_missing_baseline_is_empty():
    assert len(load_baseline("/no/such/baseline.json")) == 0
    assert len(load_baseline(None)) == 0


def test_one_baseline_entry_covers_identical_lines(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    (mod / "simulate.py").write_text(
        "def g(x):\n    return x > 1e-9\ndef h(x):\n    return x > 1e-9\n")
    bl = Baseline([BaselineEntry("RPR004", "src/repro/core/simulate.py",
                                 "return x > 1e-9", "fixture")])
    active, baselined = run_source_analysis(["src"], tmp_path, bl)
    assert active == [] and len(baselined) == 2


# --------------------------------------------------------------------------
# Report output: JSON stability + summary table
# --------------------------------------------------------------------------

def test_json_output_is_stable():
    src = ("import time\nt0 = time.time()\n"
           "def f(x):\n    return x > 1e-9\n")
    findings = analyze_source(src, GUARDED)
    assert len(findings) == 2
    one, two = render_json(findings, []), render_json(findings, [])
    assert one == two
    payload = json.loads(one)
    assert payload["version"] == 1
    assert payload["counts"] == {"active": 2, "baselined": 0}
    assert [f["code"] for f in payload["findings"]] == ["RPR001", "RPR004"]
    assert all("line_text" in f and "path" in f for f in payload["findings"])


def test_summary_table_counts_per_rule():
    findings = analyze_source(
        "import time\nt0 = time.time()\nt1 = time.time()\n", LIB)
    table = summary_table(findings, [])
    line = next(l for l in table.splitlines() if l.startswith("RPR001"))
    assert line.split()[-2:] == ["2", "0"]
    assert table.splitlines()[-1].split() == ["total", "2", "0"]


# --------------------------------------------------------------------------
# The repo itself lints clean (the acceptance gate CI enforces)
# --------------------------------------------------------------------------

def test_repo_source_is_clean_under_baseline():
    baseline = load_baseline(REPO / "analysis-baseline.json")
    active, _ = run_source_analysis(["src", "benchmarks"], REPO, baseline)
    assert active == [], "\n".join(
        f"{f.location}: {f.code} {f.message}" for f in active)


# --------------------------------------------------------------------------
# CLI: exit codes 0 / 1 / 2
# --------------------------------------------------------------------------

def _cli(args, cwd):
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src")
    return subprocess.run(
        [sys.executable, "-m", "repro.analysis", *args],
        cwd=cwd, env=env, capture_output=True, text=True)


def test_cli_exit_codes(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    target = mod / "simulate.py"

    target.write_text("def g(x):\n    return x\n")
    assert _cli(["--root", str(tmp_path)], tmp_path).returncode == 0

    target.write_text("def g(x):\n    return x > 1e-9\n")
    proc = _cli(["--root", str(tmp_path)], tmp_path)
    assert proc.returncode == 1
    assert "RPR004" in proc.stdout
    assert "src/repro/core/simulate.py:2" in proc.stdout

    bad_baseline = tmp_path / "corrupt.json"
    bad_baseline.write_text("{not json")
    proc = _cli(["--root", str(tmp_path), "--baseline", str(bad_baseline)],
                tmp_path)
    assert proc.returncode == 2


def test_cli_json_format(tmp_path):
    mod = tmp_path / "src" / "repro" / "core"
    mod.mkdir(parents=True)
    (mod / "simulate.py").write_text("def g(x):\n    return x > 1e-9\n")
    proc = _cli(["--root", str(tmp_path), "--format", "json"], tmp_path)
    payload = json.loads(proc.stdout)
    assert payload["counts"]["active"] == 1
    assert payload["findings"][0]["code"] == "RPR004"


# --------------------------------------------------------------------------
# Layer 2: the compiled-program verifier (abstract tracing only)
# --------------------------------------------------------------------------

def test_verifier_full_inventory_passes():
    from repro.analysis.programs import PROGRAM_KEYS, verify_all

    checks = verify_all()
    failed = [c for c in checks if not c.ok]
    assert not failed, "\n".join(
        f"{c.program}/{c.check}: {c.detail}" for c in failed)
    assert {c.program for c in checks} == set(PROGRAM_KEYS)
    # the fold is the only donating program and the only one with a psum
    fold = {c.check: c for c in checks
            if c.program == "learn.fold:sharded"}
    assert fold["donation"].ok and fold["collectives"].ok
    assert "'all-reduce': 1" in fold["collectives"].detail


def test_verifier_unknown_key_is_a_failure():
    from repro.analysis.programs import verify_all

    checks = verify_all(keys=["no.such.program"])
    assert [(c.program, c.check, c.ok) for c in checks] == [
        ("no.such.program", "build", False)]


def test_broken_placement_contract_fails_with_program_key():
    # The acceptance demo: smuggle a psum into a zero-collective program
    # and the verifier must fail its collectives check by name — without
    # ever executing the program.
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    from repro.analysis.programs import verify_program

    devs = np.array(jax.devices())
    mesh = Mesh(devs, ("s",))
    broken = jax.jit(shard_map(
        lambda x: jax.lax.psum(x, "s"), mesh=mesh,
        in_specs=P("s"), out_specs=P()))
    arg = jax.ShapeDtypeStruct((len(devs), 4), jnp.float32)
    checks = verify_program(broken, (arg,), key="demo.sneaky-psum",
                            collectives={"total": 0})
    (coll,) = [c for c in checks if c.check == "collectives"]
    assert not coll.ok
    assert coll.program == "demo.sneaky-psum"
    assert "off contract" in coll.detail and "total=1" in coll.detail


def test_callback_in_program_fails_check():
    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.analysis.programs import verify_program

    def leaky(x):
        return jax.pure_callback(
            lambda v: np.asarray(v) * 2.0,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)

    checks = verify_program(jax.jit(leaky),
                            (jax.ShapeDtypeStruct((4,), jnp.float32),),
                            key="demo.callback")
    (cb,) = [c for c in checks if c.check == "callbacks"]
    assert not cb.ok and "pure_callback" in cb.detail


def test_f64_program_fails_dtype_check():
    import jax
    import jax.numpy as jnp

    from repro.analysis.programs import verify_program

    if not jax.config.jax_enable_x64:
        pytest.skip("x64 disabled: f64 avals cannot be constructed")
    checks = verify_program(
        jax.jit(lambda x: x + 1.0),
        (jax.ShapeDtypeStruct((4,), jnp.float64),), key="demo.f64")
    (dt,) = [c for c in checks if c.check == "dtype"]
    assert not dt.ok


def test_invalid_donation_fails_check():
    import jax
    import jax.numpy as jnp

    from repro.analysis.programs import verify_program

    # donated (8,) input vs (4,) output: the alias can never be taken.
    checks = verify_program(
        jax.jit(lambda x: x[:4], donate_argnums=(0,)),
        (jax.ShapeDtypeStruct((8,), jnp.float32),),
        key="demo.bad-donation", donated=(0,))
    (don,) = [c for c in checks if c.check == "donation"]
    assert not don.ok and "matches NO output" in don.detail
