"""Quickstart: the paper's core pipeline in ~40 lines.

    PYTHONPATH=src python examples/quickstart.py

1. Reproduces the paper's worked example (Fig. 3/4): optimal deadline
   allocation puts 22/6 units of work on spot instances.
2. Generates a Section-6.1 job stream, prices it under the proposed policy
   (Algorithm 2) vs the Greedy/Even baselines, and runs TOLA online
   learning over the policy grid.
"""

import numpy as np

from repro.core import (
    B_BIDS,
    SpotMarket,
    chain_from_arrays,
    expected_spot_work,
    generate_chain_jobs,
    run_greedy,
    run_jobs,
    run_tola,
    spot_od_policies,
    window_sizes,
)

# --- 1. the paper's Fig. 3/4 example ---------------------------------------
job = chain_from_arrays(0.0, 4.0, z=[1.5, 0.5, 2.5, 0.5], delta=[2, 1, 3, 1])
sizes = window_sizes(job, x=0.5)   # Dealloc(beta = 0.5)
zo = expected_spot_work(job.z_array(), job.delta_array(), sizes, 0.5)
print(f"optimal windows: {np.round(sizes, 4)}  "
      f"spot workload: {zo.sum():.4f} (= 22/6, paper Fig. 4)")

# --- 2. a job stream under the proposed policy vs baselines -----------------
jobs = generate_chain_jobs(300, job_type=1, seed=7)
market = SpotMarket(max(j.deadline for j in jobs) + 1, seed=11)

best = min(run_jobs(jobs, p, market).average_unit_cost()
           for p in spot_od_policies())
greedy = min(run_greedy(jobs, b, market).average_unit_cost() for b in B_BIDS)
even = min(run_jobs(jobs, p, market, windows="even",
                    early_start=False).average_unit_cost()
           for p in spot_od_policies())
print(f"alpha proposed {best:.4f} | greedy {greedy:.4f} | even {even:.4f}")
print(f"cost improvement: {1 - best / greedy:.2%} vs greedy, "
      f"{1 - best / even:.2%} vs even")

# --- 3. online learning (TOLA) over the policy grid -------------------------
res = run_tola(jobs, spot_od_policies(), market, seed=0)
print(f"TOLA realized alpha {res.average_unit_cost():.4f}, "
      f"best fixed {res.best_fixed_unit_cost:.4f}, "
      f"top policy weight {res.weights.max():.3f}")
