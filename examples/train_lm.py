"""End-to-end training driver.

Default: a ~100M-parameter llama-style model for a few hundred steps on the
available devices, with checkpoints + deterministic data. On this CPU
container prefer the quick demo:

    PYTHONPATH=src python examples/train_lm.py --quick        # ~2 min
    PYTHONPATH=src python examples/train_lm.py                # ~100M params
    PYTHONPATH=src python examples/train_lm.py --elastic      # preempt+resume
"""

import argparse

from repro.launch.train import train_loop
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    """~100M params: 12L, d=768, llama-style (GQA + SwiGLU + rotary)."""
    return ModelConfig(
        name="lm-100m", kind="decoder", n_layers=12, d_model=768,
        n_heads=12, n_kv_heads=4, d_ff=2048, vocab=32000)


def model_quick() -> ModelConfig:
    return ModelConfig(
        name="lm-quick", kind="decoder", n_layers=4, d_model=256,
        n_heads=8, n_kv_heads=2, d_ff=688, vocab=4096)


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true")
    p.add_argument("--elastic", action="store_true")
    p.add_argument("--steps", type=int, default=None)
    p.add_argument("--ckpt-dir", default="/tmp/repro_train_lm")
    args = p.parse_args()

    cfg = model_quick() if args.quick else model_100m()
    steps = args.steps or (60 if args.quick else 300)
    batch, seq = (8, 128) if args.quick else (16, 512)
    print(f"[example] {cfg.name}: {cfg.params_dense/1e6:.0f}M params, "
          f"{steps} steps, batch {batch} x seq {seq}")
    if args.elastic:
        r = train_loop(cfg, steps, args.ckpt_dir, batch, seq,
                       preempt_at=steps // 2, ckpt_every=10)
        print(f"[example] preempted at {r['step']}; restarting (elastic)")
        r = train_loop(cfg, steps, args.ckpt_dir, batch, seq, resume=True,
                       ckpt_every=10)
    else:
        r = train_loop(cfg, steps, args.ckpt_dir, batch, seq, ckpt_every=50)
    print(f"[example] {r['status']} @ step {r['step']}, "
          f"loss {r['losses'][0]:.3f} -> {r['losses'][-1]:.3f}")


if __name__ == "__main__":
    main()
