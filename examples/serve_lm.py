"""Batched serving example: prefill + lockstep decode with slot reuse.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2_2_7b]
"""

import argparse

import numpy as np

from repro.configs import smoke_config
from repro.launch.serve import serve_requests


def main():
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="tinyllama_1_1b")
    p.add_argument("--requests", type=int, default=8)
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--max-new", type=int, default=16)
    args = p.parse_args()

    cfg = smoke_config(args.arch)
    rng = np.random.default_rng(0)
    prompts = rng.integers(0, cfg.vocab, (args.requests, 24), dtype=np.int32)
    out, stats = serve_requests(cfg, prompts, args.batch, args.max_new)
    print(f"[example] served {stats['requests']} requests "
          f"@ {stats['tokens_per_s']:.1f} tok/s")
    for i in range(min(3, len(out))):
        print(f"  completion {i}: {out[i][:10].tolist()}")


if __name__ == "__main__":
    main()
