"""The two layers together: the paper's scheduler pricing a fleet of
training jobs whose stage workloads come from the compiled dry-run roofline.

    PYTHONPATH=src python examples/fleet_schedule.py

A month of arriving pretraining jobs (DAGs: tokenize -> train segments ->
evals -> export) is scheduled against reserved/preemptible/on-demand TPU
pods. TOLA learns the policy knobs {beta, beta_0, bid} online; the report
shows where the work ran and what it cost vs naive alternatives.
"""

import numpy as np

from repro.sched import FleetOrchestrator, FleetSpec, training_job_dag
from repro.sched.fleet import load_roofline_cache

cache = load_roofline_cache()
archs = ["llama3_8b", "mamba2_2_7b", "deepseek_moe_16b", "qwen2_5_32b"]

rng = np.random.default_rng(0)
arrivals = np.cumsum(rng.exponential(2.0, 60))   # ~1 job / 2h over ~5 days
jobs = [training_job_dag(archs[i % len(archs)], float(a),
                         deadline_factor=float(rng.uniform(1.5, 3.0)),
                         max_pods=8, cache=cache)
        for i, a in enumerate(arrivals)]
print(f"[fleet] {len(jobs)} training jobs, "
      f"{sum(j.l for j in jobs)} stages, "
      f"total work {sum(j.total_work for j in jobs):.0f} pod-hours")

for reserved in (0, 4, 8):
    orch = FleetOrchestrator(FleetSpec(reserved_pods=reserved),
                             horizon_units=float(arrivals[-1] + 100))
    rep = orch.schedule(jobs, learn=True)
    print(f"[fleet] reserved={reserved}: unit cost {rep.unit_cost:.4f} "
          f"(spot {rep.spot_fraction:.0%} / self {rep.selfowned_fraction:.0%}"
          f" / on-demand {rep.ondemand_fraction:.0%}) "
          f"best policy beta={rep.best_policy.beta:.2f} "
          f"bid={rep.best_policy.bid}")
print("[fleet] all-on-demand reference unit cost: 1.0000")
